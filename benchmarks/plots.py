"""Sweep-native plotting: paper-style figures straight from sweep artifacts.

Renders fig5/fig6-style figures directly from a :class:`SweepResult`
artifact (the CSV/JSON written by ``SweepResult.to_csv/to_json``) or from
in-memory aggregated rows, so any sweep -- the 270-cell example grid, a 10k
cluster grid, CI's cross-check artifact -- can be turned into the paper's
plots without re-running the simulation:

* **policy curves** (fig5-style): a metric (R_avg, R_p95, S_avg, ...) vs
  intensity, one line per policy, one panel per (arrival, cores) slice.
* **node frontier** (fig6-style): the metric vs node count, one line per
  mode/policy series -- the "3 machines with scheduling beat 4 stock
  machines" claim as a frontier curve.

Usage::

    python -m benchmarks.plots sweep.csv --out plots/
    python -m benchmarks.plots sweep.json --out plots/ --metric R_p95
    python examples/sweep_grid.py --quick --plot plots/   # end-to-end
"""

from __future__ import annotations

import csv
import json
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# numeric columns in aggregate rows (everything else stays a string)
_STR_COLS = {"policy", "mode", "assignment", "lb", "arrival", "backend",
             "label", "fail_spec", "node_speeds", "degrade", "scenario",
             "retry_mode"}


def _coerce(key: str, val):
    if val is None or val == "":
        return None
    if key in _STR_COLS:
        return val
    if val in ("True", "False"):          # CSV round-trip of bool axes
        return val == "True"
    try:
        f = float(val)
    except (TypeError, ValueError):
        return val
    return f


def load_rows(path: str | Path) -> list[dict]:
    """Aggregated sweep rows from a ``SweepResult`` CSV or JSON artifact."""
    path = Path(path)
    if path.suffix == ".json":
        payload = json.loads(path.read_text())
        if isinstance(payload, list):        # bare row list (engine_bench)
            rows = payload
        else:
            rows = payload.get("aggregate", [])
    else:
        with open(path, newline="") as fh:
            rows = list(csv.DictReader(fh))
    out = [{k: _coerce(k, v) for k, v in row.items()} for row in rows]
    if not out:
        raise ValueError(f"no aggregated sweep rows in {path}")
    return out


def _fig(n_panels: int):
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    cols = min(n_panels, 3)
    rows = (n_panels + cols - 1) // cols
    fig, axes = plt.subplots(rows, cols, figsize=(4.6 * cols, 3.4 * rows),
                             squeeze=False)
    return fig, [ax for row in axes for ax in row]


def _series_sorted(rows, x_key):
    return sorted(rows, key=lambda r: r[x_key])


def plot_policy_curves(rows: list[dict], metric: str = "R_avg",
                       out: str | Path = "sweep_policies.png") -> Path:
    """fig5-style: ``metric`` vs intensity, one line per policy, a panel per
    (arrival, cores, nodes) slice present in the artifact."""
    panels: dict[tuple, list[dict]] = {}
    for r in rows:
        if r.get("intensity") is None or r.get(metric) is None:
            continue
        key = (r.get("arrival", "uniform"), r.get("cores"), r.get("nodes"))
        panels.setdefault(key, []).append(r)
    if not panels:
        raise ValueError(f"artifact has no (intensity, {metric}) rows")
    fig, axes = _fig(len(panels))
    for ax, (key, prows) in zip(axes, sorted(panels.items(),
                                             key=lambda kv: str(kv[0]))):
        arrival, cores, nodes = key
        by_policy: dict[str, list[dict]] = {}
        for r in prows:
            by_policy.setdefault(str(r.get("policy")), []).append(r)
        for pol, srows in sorted(by_policy.items()):
            pts = _series_sorted(srows, "intensity")
            ax.plot([p["intensity"] for p in pts], [p[metric] for p in pts],
                    marker="o", markersize=3, linewidth=1.4, label=pol)
        title = f"{arrival}, c={cores:g}"
        if nodes and nodes != 1:
            title += f", n={nodes:g}"
        ax.set_title(title, fontsize=10)
        ax.set_xlabel("intensity")
        ax.set_ylabel(f"{metric} (s)" if metric.startswith("R") else metric)
        ax.grid(alpha=0.3)
        ax.legend(fontsize=8)
    for ax in axes[len(panels):]:
        ax.set_visible(False)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    import matplotlib.pyplot as plt
    plt.close(fig)
    return Path(out)


def plot_node_frontier(rows: list[dict], metric: str = "R_avg",
                       out: str | Path = "sweep_nodes.png") -> Path:
    """fig6-style: ``metric`` vs node count, one line per mode/policy series
    (per arrival/intensity slice) -- fewer-machines-same-tail frontiers."""
    panels: dict[tuple, list[dict]] = {}
    for r in rows:
        if r.get("nodes") is None or r.get(metric) is None:
            continue
        key = (r.get("arrival", "uniform"), r.get("intensity"))
        panels.setdefault(key, []).append(r)
    panels = {k: v for k, v in panels.items()
              if len({r["nodes"] for r in v}) > 1}
    if not panels:
        raise ValueError(f"artifact has no multi-node (nodes, {metric}) rows")
    fig, axes = _fig(len(panels))
    for ax, (key, prows) in zip(axes, sorted(panels.items(),
                                             key=lambda kv: str(kv[0]))):
        arrival, intensity = key
        series: dict[str, list[dict]] = {}
        for r in prows:
            name = f"{r.get('mode', 'ours')}-{r.get('policy')}"
            series.setdefault(name, []).append(r)
        for name, srows in sorted(series.items()):
            pts = _series_sorted(srows, "nodes")
            ax.plot([p["nodes"] for p in pts], [p[metric] for p in pts],
                    marker="s", markersize=3.5, linewidth=1.4, label=name)
        ax.set_title(f"{arrival}, v={intensity:g}", fontsize=10)
        ax.set_xlabel("nodes")
        ax.set_ylabel(f"{metric} (s)" if metric.startswith("R") else metric)
        ax.grid(alpha=0.3)
        ax.legend(fontsize=8)
    for ax in axes[len(panels):]:
        ax.set_visible(False)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    import matplotlib.pyplot as plt
    plt.close(fig)
    return Path(out)


def plot_frontier(rows: list[dict], metric: str = "R_p95",
                  out: str | Path = "sweep_frontier.png") -> Path:
    """Autoscaler frontier: ``metric`` (a tail percentile) vs node count,
    one line per provision delay plus a ``static`` line for autoscale-off
    rows -- the paper's "fewer machines, same tail" claim as a family of
    frontier curves.  Panels per (policy, intensity) slice."""
    panels: dict[tuple, list[dict]] = {}
    for r in rows:
        if r.get("nodes") is None or r.get(metric) is None:
            continue
        key = (str(r.get("policy")), r.get("intensity"))
        panels.setdefault(key, []).append(r)
    panels = {k: v for k, v in panels.items()
              if len({r["nodes"] for r in v}) > 1
              and any(r.get("autoscale") for r in v)}
    if not panels:
        raise ValueError(
            f"artifact has no autoscale frontier rows for {metric} "
            "(needs nodes + autoscale axes)")
    fig, axes = _fig(len(panels))
    for ax, (key, prows) in zip(axes, sorted(panels.items(),
                                             key=lambda kv: str(kv[0]))):
        policy, intensity = key
        series: dict[str, list[dict]] = {}
        for r in prows:
            if r.get("autoscale"):
                pd = r.get("provision_delay")
                name = f"provision {pd:g}s" if pd is not None else "autoscale"
            else:
                name = "static fleet"
            series.setdefault(name, []).append(r)
        for name, srows in sorted(series.items()):
            pts = _series_sorted(srows, "nodes")
            style = dict(marker="o", markersize=3.5, linewidth=1.4)
            if name == "static fleet":
                style.update(color="black", linestyle="--", marker="s")
            ax.plot([p["nodes"] for p in pts], [p[metric] for p in pts],
                    label=name, **style)
        ax.set_title(f"{policy}, v={intensity:g}", fontsize=10)
        ax.set_xlabel("initial nodes")
        ax.set_ylabel(f"{metric} (s)" if metric.startswith("R") else metric)
        ax.grid(alpha=0.3)
        ax.legend(fontsize=8)
    for ax in axes[len(panels):]:
        ax.set_visible(False)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    import matplotlib.pyplot as plt
    plt.close(fig)
    return Path(out)


def _parse_tuple(val):
    """A tuple-valued sweep column (in-memory or its CSV string form)."""
    if val in (None, "", "None"):
        return None
    if isinstance(val, str):
        import ast
        try:
            val = ast.literal_eval(val)
        except (SyntaxError, ValueError):
            return None
    return val


def row_severity(row: dict) -> float:
    """Worst effective slowdown a sweep row declares (1.0 = healthy fleet),
    delegating to :meth:`NodeSpeedProfile.max_slowdown` so static
    ``node_speeds`` heterogeneity and ``degrade`` episodes both count --
    this is the x-axis of the straggler frontier."""
    from repro.core import NodeSpeedProfile
    try:
        prof = NodeSpeedProfile.from_any(
            _parse_tuple(row.get("node_speeds")),
            _parse_tuple(row.get("degrade")) or ())
    except (ValueError, TypeError):
        # malformed column (flat or scalar episode): healthy, not a crash
        return 1.0
    return prof.max_slowdown() if prof is not None else 1.0


def plot_straggler(rows: list[dict], metric: str = "R_p95",
                   out: str | Path = "sweep_straggler.png") -> Path:
    """Straggler frontier: ``metric`` (a tail percentile) vs degradation
    severity (the worst episode slowdown), one line per
    assignment/balancer x hedged-or-not series -- "hedging recovers most of
    the p95 a slow node costs the push model, pull rides it out" as a
    figure.  Panels per (policy, intensity) slice."""
    panels: dict[tuple, list[dict]] = {}
    for r in rows:
        if r.get(metric) is None:
            continue
        key = (str(r.get("policy")), r.get("intensity"))
        panels.setdefault(key, []).append(r)
    panels = {k: v for k, v in panels.items()
              if len({row_severity(r) for r in v}) > 1}
    if not panels:
        raise ValueError(
            f"artifact has no straggler rows for {metric} "
            "(needs a degrade axis)")
    fig, axes = _fig(len(panels))
    for ax, (key, prows) in zip(axes, sorted(panels.items(),
                                             key=lambda kv: str(kv[0]))):
        policy, intensity = key
        series: dict[str, list[dict]] = {}
        for r in prows:
            name = str(r.get("assignment", "pull"))
            if name == "push" and r.get("lb") not in (None, "least_loaded"):
                name = f"push-{r['lb']}"
            if r.get("hedge_multiple") not in (None, ""):
                name += f" hedge{r['hedge_multiple']:g}"
            series.setdefault(name, []).append(r)
        for name, srows in sorted(series.items()):
            pts = sorted(srows, key=row_severity)
            style = dict(marker="o", markersize=3.5, linewidth=1.4)
            if "hedge" in name:
                style.update(linestyle="-")
            elif name.startswith("pull"):
                style.update(linestyle=":", marker="^")
            else:
                style.update(linestyle="--", marker="s")
            ax.plot([row_severity(p) for p in pts],
                    [p[metric] for p in pts], label=name, **style)
        ax.set_title(f"{policy}, v={intensity:g}", fontsize=10)
        ax.set_xlabel("degradation severity (x slow)")
        ax.set_ylabel(f"{metric} (s)" if metric.startswith("R") else metric)
        ax.grid(alpha=0.3)
        ax.legend(fontsize=8)
    for ax in axes[len(panels):]:
        ax.set_visible(False)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    import matplotlib.pyplot as plt
    plt.close(fig)
    return Path(out)


def plot_storm(rows: list[dict], metric: str = "goodput",
               out: str | Path = "sweep_storm.png") -> Path:
    """Metastable-overload hysteresis: time-binned goodput, one line per
    retry/shedding scenario, the ramp's burst window shaded -- "naive
    immediate retries keep the cluster depressed after the burst releases,
    capped backoff + admission control recovers" as a figure.  Consumes the
    ``storm_series.csv`` rows written by ``engine_bench --rows storm``
    (columns: scenario, t, goodput[, burst_t0, burst_t1])."""
    srows = [r for r in rows
             if r.get("scenario") not in (None, "")
             and r.get("t") is not None and r.get(metric) is not None]
    if not srows:
        raise ValueError(
            f"artifact has no storm series rows for {metric} "
            "(needs scenario/t columns from engine_bench --rows storm)")
    series: dict[str, list[dict]] = {}
    for r in srows:
        series.setdefault(str(r["scenario"]), []).append(r)
    fig, axes = _fig(1)
    ax = axes[0]
    b0 = next((r["burst_t0"] for r in srows
               if r.get("burst_t0") not in (None, "")), None)
    b1 = next((r["burst_t1"] for r in srows
               if r.get("burst_t1") not in (None, "")), None)
    if b0 is not None and b1 is not None:
        ax.axvspan(float(b0), float(b1), color="0.88", zorder=0,
                   label="burst window")
    for name, pts in sorted(series.items()):
        pts = _series_sorted(pts, "t")
        style = dict(linewidth=1.5, markersize=2.8)
        if "backoff" in name:
            style.update(linestyle="-", marker="o")
        elif "naive" in name:
            style.update(linestyle="--", marker="s")
        else:
            style.update(linestyle=":", marker="^")
        ax.plot([p["t"] for p in pts], [p[metric] for p in pts],
                label=name, **style)
    ax.set_xlabel("time (s)")
    ax.set_ylabel("goodput (completions/s)")
    ax.set_title("retry-storm hysteresis (ramp-and-release)", fontsize=10)
    ax.grid(alpha=0.3)
    ax.legend(fontsize=8)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    import matplotlib.pyplot as plt
    plt.close(fig)
    return Path(out)


def plot_planet(rows: list[dict], out: str | Path = "planet_rate.png") -> Path:
    """Planet-scale streaming replay: time-binned completions/s against the
    autoscaler's provisioned node count on a twin axis -- "the fleet grows
    into the offered load and throughput follows" as a figure.  Consumes
    the ``planet_series.csv`` rows written by ``engine_bench --rows planet``
    (columns: t, rate, nodes)."""
    srows = [r for r in rows
             if r.get("t") is not None and r.get("rate") is not None
             and r.get("nodes") is not None]
    if not srows:
        raise ValueError(
            "artifact has no planet series rows "
            "(needs t/rate/nodes columns from engine_bench --rows planet)")
    srows = _series_sorted(srows, "t")
    fig, axes = _fig(1)
    ax = axes[0]
    hours = [r["t"] / 3600.0 for r in srows]
    ax.plot(hours, [r["rate"] for r in srows], color="tab:blue",
            linewidth=1.5, label="completions/s")
    ax.set_xlabel("stream time (h)")
    ax.set_ylabel("completions/s", color="tab:blue")
    ax.tick_params(axis="y", labelcolor="tab:blue")
    ax2 = ax.twinx()
    ax2.plot(hours, [r["nodes"] for r in srows], color="tab:red",
             linewidth=1.3, linestyle="--", label="provisioned nodes")
    ax2.set_ylabel("provisioned nodes", color="tab:red")
    ax2.tick_params(axis="y", labelcolor="tab:red")
    ax.set_title("planet replay: throughput vs fleet size", fontsize=10)
    ax.grid(alpha=0.3)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    import matplotlib.pyplot as plt
    plt.close(fig)
    return Path(out)


def plot_timeline(trace_or_probes, out: str | Path = "flight_timeline.png",
                  window_s: float | None = None, bins: int = 64) -> Path:
    """Flight-recorder timeline: the windowed probes of a
    :class:`repro.core.SimTrace` as a two-panel ribbon -- utilization and
    queue/backlog levels on top, per-window event rates (arrivals,
    completions, retries/timeouts/sheds/steals when present) below.
    Accepts either a ``SimTrace`` (probes are computed here) or the dict
    returned by ``SimTrace.probes()``."""
    probes = trace_or_probes
    if hasattr(trace_or_probes, "probes"):
        probes = trace_or_probes.probes(window_s, bins=bins)
    if not isinstance(probes, dict) or "t" not in probes:
        raise ValueError("expected a SimTrace or a SimTrace.probes() dict")
    t = probes["t"]
    if not t:
        raise ValueError("trace has no probe windows")
    fig, axes = _fig(2)
    ax = axes[0]
    ax.plot(t, probes["utilization"], color="tab:blue", linewidth=1.5,
            label="utilization")
    ax.set_ylabel("utilization", color="tab:blue")
    ax.tick_params(axis="y", labelcolor="tab:blue")
    ax.set_ylim(bottom=0)
    ax2 = ax.twinx()
    ax2.plot(t, probes["queue_depth"], color="tab:red", linewidth=1.3,
             linestyle="--", label="queue depth")
    if any(probes.get("channel_backlog", ())):
        ax2.plot(t, probes["channel_backlog"], color="tab:orange",
                 linewidth=1.2, linestyle=":", label="channel backlog")
    ax2.set_ylabel("queued calls", color="tab:red")
    ax2.tick_params(axis="y", labelcolor="tab:red")
    ax2.set_ylim(bottom=0)
    ax.set_xlabel("time (s)")
    ax.set_title("load: utilization and queueing", fontsize=10)
    ax.grid(alpha=0.3)
    ax = axes[1]
    ax.plot(t, probes["arrivals"], linewidth=1.5, label="arrivals")
    ax.plot(t, probes["completions"], linewidth=1.5, linestyle="--",
            label="completions")
    for key in ("retries", "timeouts", "sheds", "steals"):
        if any(probes.get(key, ())):
            ax.plot(t, probes[key], linewidth=1.2, linestyle=":", label=key)
    ax.set_xlabel("time (s)")
    ax.set_ylabel("events / window")
    ax.set_title("lifecycle event rates", fontsize=10)
    ax.grid(alpha=0.3)
    ax.legend(fontsize=8)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    import matplotlib.pyplot as plt
    plt.close(fig)
    return Path(out)


def render_rows(rows: list[dict], outdir: str | Path,
                metrics: tuple[str, ...] = ("R_avg",)) -> list[Path]:
    """Render every figure the artifact supports: policy curves when an
    intensity axis exists, node frontiers when a nodes axis exists,
    autoscaler frontier curves when autoscale rows are present, and
    straggler frontiers when a degrade axis exists."""
    outdir = Path(outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    written: list[Path] = []
    for metric in metrics:
        try:
            written.append(plot_policy_curves(
                rows, metric, outdir / f"policies_{metric}.png"))
        except ValueError:
            pass
        try:
            written.append(plot_node_frontier(
                rows, metric, outdir / f"nodes_{metric}.png"))
        except ValueError:
            pass
        try:
            written.append(plot_frontier(
                rows, metric, outdir / f"frontier_{metric}.png"))
        except ValueError:
            pass
        try:
            written.append(plot_straggler(
                rows, metric, outdir / f"straggler_{metric}.png"))
        except ValueError:
            pass
    try:
        written.append(plot_storm(rows, out=outdir / "storm_goodput.png"))
    except ValueError:
        pass
    try:
        written.append(plot_planet(rows, out=outdir / "planet_rate.png"))
    except ValueError:
        pass
    if not written:
        raise ValueError(
            f"artifact supports none of the figures for metrics {metrics} "
            "(needs an intensity or nodes axis)")
    return written


def render(path: str | Path, outdir: str | Path,
           metrics: tuple[str, ...] = ("R_avg",)) -> list[Path]:
    """Load a sweep artifact and render its figures into ``outdir``."""
    return render_rows(load_rows(path), outdir, metrics)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(
        description="render fig5/fig6-style figures from a sweep artifact")
    ap.add_argument("artifact", help="SweepResult .csv or .json")
    ap.add_argument("--out", default="plots", help="output directory")
    ap.add_argument("--metric", action="append", default=None,
                    help="metric column(s) to plot (default: R_avg)")
    args = ap.parse_args()
    metrics = tuple(args.metric) if args.metric else ("R_avg",)
    for p in render(args.artifact, args.out, metrics):
        print(f"wrote {p}")


if __name__ == "__main__":
    main()
