"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import sys
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

import numpy as np  # noqa: E402

from repro.core import generate_burst, simulate_single_node, summarize  # noqa: E402


def run_config(cores: int, intensity: int, policy: str, mode: str,
               seeds: int = 3, **kw):
    """Aggregate one (cores, intensity, policy, mode) configuration."""
    rows = []
    colds = []
    for seed in range(seeds):
        reqs = generate_burst(cores=cores, intensity=intensity, seed=seed)
        res = simulate_single_node(reqs, cores=cores, policy=policy,
                                   mode=mode, **kw)
        rows.append(summarize(reqs))
        colds.append(res.cold_starts)
    return {
        "R_avg": float(np.mean([s.response_avg for s in rows])),
        "R_p50": float(np.mean([s.response_pct[50] for s in rows])),
        "R_p75": float(np.mean([s.response_pct[75] for s in rows])),
        "R_p95": float(np.mean([s.response_pct[95] for s in rows])),
        "R_p99": float(np.mean([s.response_pct[99] for s in rows])),
        "S_avg": float(np.mean([s.stretch_avg for s in rows])),
        "S_p50": float(np.mean([s.stretch_pct[50] for s in rows])),
        "max_c": float(np.mean([s.max_completion for s in rows])),
        "cold": float(np.mean(colds)),
    }


def emit(rows: list[dict]) -> None:
    """Print the harness-wide CSV contract: name,us_per_call,derived."""
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
