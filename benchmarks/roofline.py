"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, derive the three roofline terms on TPU v5e:

    compute    = HLO_FLOPs_per_chip / 197 TFLOP/s (bf16)
    memory     = HLO_bytes_per_chip / 819 GB/s HBM
    collective = collective_bytes_per_chip / 50 GB/s ICI

plus MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill/decode), N = active
params, and the usefulness ratio MODEL_FLOPS / (HLO_FLOPs * chips) that
catches remat/dispatch waste.  The dominant term is the bottleneck the
perf loop iterates on (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import json
from pathlib import Path

from .common import emit

from repro.configs import ALIASES, SHAPES, get_config

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per chip (ICI)

ART_DIR = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs (global, whole step).  6*N*D for train,
    2*N*D for inference, N = active params; enc-dec splits the stacks
    (encoder params see encoder tokens, decoder params decoder tokens)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.param_count(active_only=True)
    mult = 6.0 if shape.step == "train" else 2.0
    if cfg.is_encdec:
        d = cfg.d_model
        per_enc_layer = 4 * d * cfg.head_dim * cfg.n_heads // 1 \
            + 3 * d * cfg.d_ff  # rough: attn + mlp
        n_enc = cfg.encoder_layers * (4 * d * d + 3 * d * cfg.d_ff)
        n_dec = n_active - n_enc
        enc_tokens = shape.global_batch * shape.seq_len
        dec_tokens = shape.global_batch * (shape.seq_len // cfg.decoder_ratio)
        if shape.step == "decode":
            dec_tokens = shape.global_batch
            return mult * n_dec * dec_tokens       # encoder not re-run
        return mult * (n_enc * enc_tokens + n_dec * dec_tokens)
    if shape.step == "decode":
        return mult * n_active * shape.global_batch
    return mult * n_active * shape.global_batch * shape.seq_len


def analyse(rec: dict) -> dict:
    devices = rec["devices"]
    t_compute = rec["flops"] / PEAK_FLOPS
    t_memory = rec["bytes_accessed"] / HBM_BW
    t_coll = rec["collective_bytes_total"] / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(rec["flops"] * devices, 1.0)
    # fraction of the bound step time that is useful compute
    t_bound = max(terms.values())
    t_useful = (mf / devices) / PEAK_FLOPS
    return {
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": t_useful / t_bound if t_bound > 0 else 0.0,
    }


def analyse_scan_buckets(recs: list[dict]) -> list[dict]:
    """Roofline-style breakdown for the simulator's scan path: aggregate the
    per-dispatch timing records from ``repro.core.scan_bucket_timings()``
    into one row per bucket shape, splitting the wall into host build (row
    fill), XLA compile, async dispatch (enqueue) and host sync (the block on
    results), with the dominant term named -- the mega-sweep analogue of the
    TPU compute/memory/collective split above."""
    by_bucket: dict[str, dict] = {}
    for r in recs:
        agg = by_bucket.setdefault(r["bucket"], {
            "bucket": r["bucket"], "bsz": r["bsz"], "cells": 0,
            "chunks": 0, "build_s": 0.0, "compile_s": 0.0,
            "dispatch_s": 0.0, "sync_s": 0.0, "tune_s": 0.0})
        agg["cells"] += r["cells"]
        agg["chunks"] += 1 if r["cells"] else 0   # tune records aren't chunks
        agg["bsz"] = max(agg["bsz"], r["bsz"])
        for k in ("build_s", "compile_s", "dispatch_s", "sync_s"):
            agg[k] += r[k]
        agg["tune_s"] += r.get("tune_s", 0.0)
    out = []
    for agg in by_bucket.values():
        terms = {k: agg[k] for k in ("build_s", "compile_s",
                                     "dispatch_s", "sync_s", "tune_s")}
        agg["dominant"] = max(terms, key=terms.get)
        agg["total_s"] = sum(terms.values())
        agg["cells_per_s"] = (agg["cells"] / agg["total_s"]
                              if agg["total_s"] > 0 else 0.0)
        out.append(agg)
    out.sort(key=lambda a: -a["total_s"])
    return out


def load_records(mesh: str = "sp") -> list[dict]:
    recs = []
    for f in sorted(ART_DIR.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        if r.get("ok"):
            recs.append(r)
    return recs


def run(quick: bool = False) -> list[dict]:
    rows = []
    for rec in load_records("sp"):
        a = analyse(rec)
        step_time = max(a["compute"], a["memory"], a["collective"])
        rows.append({
            "name": f"roofline/{ALIASES.get(rec['arch'], rec['arch'])}"
                    f"_{rec['shape']}",
            "us_per_call": step_time * 1e6,      # bound step time
            "derived": (f"dominant={a['dominant']};"
                        f"compute_ms={a['compute']*1e3:.2f};"
                        f"memory_ms={a['memory']*1e3:.2f};"
                        f"collective_ms={a['collective']*1e3:.2f};"
                        f"useful={a['useful_ratio']:.2f};"
                        f"roofline_frac={a['roofline_fraction']:.3f}"),
        })
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick))


if __name__ == "__main__":
    main()
