"""Trace replay: the policies scored on production-shaped load.

Replays the vendored Azure-Functions-shaped slice (``data/
azure_trace_slice.csv``: 32 functions x 15 minutes, heavy-tailed rates with
a mid-window burst -- ~3.9k invocations, ~87% management-channel load with
transient overload) instead of the paper's synthetic 60-second bursts.
Unknown function names map deterministically (CRC32) onto SeBS profiles.

The interesting outcome mirrors the paper's low-intensity result: the stock
baseline's hot-container path bypasses the serialized management channel, so
it wins while the node is only moderately loaded, whereas under the ours
model SEPT/FC cut FIFO's mean response ~2x during the burst backlog."""

from pathlib import Path

from .common import emit

from repro.core import SweepSpec, run_sweep

TRACE = Path(__file__).resolve().parent.parent / "data" / "azure_trace_slice.csv"

POLICIES = ("baseline", "fifo", "sept", "eect", "rect", "fc")


def spec(quick: bool = False, backend: str = "auto") -> SweepSpec:
    return SweepSpec(
        policies=("baseline", "fifo", "sept", "fc") if quick else POLICIES,
        arrivals=("trace",),
        intensities=(0,),         # volume comes from the trace, not the grid
        cores=(10,),
        seeds=1 if quick else 3,
        trace_path=str(TRACE),
        backends=(backend,),
    )


def run(quick: bool = False, backend: str = "auto") -> list[dict]:
    result = run_sweep(spec(quick, backend))
    rows = []
    for r in result.aggregate():
        rows.append({
            "name": f"trace/{r['policy']}",
            "us_per_call": r["R_avg"] * 1e6,
            "derived": (f"R_avg={r['R_avg']:.2f};R_p95={r['R_p95']:.1f};"
                        f"S_avg={r['S_avg']:.0f};n={r['n']:.0f};"
                        f"cold={r['cold']:.0f}"),
        })
    return rows


def main(quick: bool = False, backend: str = "auto") -> None:
    emit(run(quick, backend))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="auto")
    args = ap.parse_args()
    main(args.quick, args.backend)
