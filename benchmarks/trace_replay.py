"""Trace replay: the policies scored on production-shaped load.

Replays the vendored Azure-Functions-shaped slice (``data/
azure_trace_slice.csv``: 32 functions x 15 minutes, heavy-tailed rates with
a mid-window burst -- ~3.9k invocations, ~87% management-channel load with
transient overload) instead of the paper's synthetic 60-second bursts.
Unknown function names map deterministically (CRC32) onto SeBS profiles.

The interesting outcome mirrors the paper's low-intensity result: the stock
baseline's hot-container path bypasses the serialized management channel, so
it wins while the node is only moderately loaded, whereas under the ours
model SEPT/FC cut FIFO's mean response ~2x during the burst backlog.

``--repeat N`` tiles the slice into an N x 15-minute stream (``--scale``
multiplies the per-minute rates) and replays it through the **vectorized**
backend -- exact for the ours node at any length -- reporting per-window
tail curves (p95 per 15-minute window), i.e. how each policy rides the
recurring burst over an hours-scale diurnal stream."""

from pathlib import Path

from .common import emit

import numpy as np

from repro.core import SweepSpec, run_sweep, simulate_single_node
from repro.core.request import Request
from repro.core.traces import iter_tiled_chunks, load_azure_trace

TRACE = Path(__file__).resolve().parent.parent / "data" / "azure_trace_slice.csv"

POLICIES = ("baseline", "fifo", "sept", "eect", "rect", "fc")


def spec(quick: bool = False, backend: str = "auto") -> SweepSpec:
    return SweepSpec(
        policies=("baseline", "fifo", "sept", "fc") if quick else POLICIES,
        arrivals=("trace",),
        intensities=(0,),         # volume comes from the trace, not the grid
        cores=(10,),
        seeds=1 if quick else 3,
        trace_path=str(TRACE),
        backends=(backend,),
    )


def run(quick: bool = False, backend: str = "auto") -> list[dict]:
    result = run_sweep(spec(quick, backend))
    rows = []
    for r in result.aggregate():
        rows.append({
            "name": f"trace/{r['policy']}",
            "us_per_call": r["R_avg"] * 1e6,
            "derived": (f"R_avg={r['R_avg']:.2f};R_p95={r['R_p95']:.1f};"
                        f"S_avg={r['S_avg']:.0f};n={r['n']:.0f};"
                        f"cold={r['cold']:.0f}"),
        })
    return rows


def diurnal_rows(repeat: int = 4, scale: float = 1.0,
                 policies: tuple[str, ...] = ("fifo", "sept", "fc"),
                 cores: int = 10, window_min: float = 15.0,
                 seed: int = 0) -> list[dict]:
    """Multi-hour replay: tile the slice ``repeat`` times and report p95
    response per ``window_min`` window of *arrival* time for each policy.

    Runs on the vectorized backend (exact, no always-warm restriction), so
    an hours-scale stream finishes in seconds.  The tiled stream is
    generated lazily (:func:`~repro.core.traces.iter_tiled_chunks`): the
    tiled per-minute trace never exists in host memory, only each minute's
    slab -- ``tile_trace``'s O(repeat x n) materialization is gone."""
    trace = load_azure_trace(TRACE)
    fns = sorted(trace)
    rows = []
    for policy in policies:
        reqs = []
        for ch in iter_tiled_chunks(trace, seed=seed, repeat=repeat,
                                    scale=scale):
            reqs.extend(Request(fn=fns[fi], r=float(t), p_true=float(p))
                        for t, fi, p in zip(ch.r, ch.fn, ch.p))
        simulate_single_node(reqs, cores=cores, policy=policy,
                             backend="vectorized")
        win = np.array([int(r.r // (window_min * 60.0)) for r in reqs])
        resp = np.array([r.response_time for r in reqs])
        p95s = [float(np.percentile(resp[win == w], 95))
                for w in range(win.max() + 1)]
        curve = ",".join(f"{v:.1f}" for v in p95s)
        rows.append({
            "name": f"trace/diurnal/{policy}",
            "us_per_call": float(resp.mean()) * 1e6,
            "derived": (f"R_avg={resp.mean():.2f};repeat={repeat};"
                        f"scale={scale:g};n={len(reqs)};"
                        f"p95_by_{window_min:g}min={curve}"),
        })
    return rows


def main(quick: bool = False, backend: str = "auto", repeat: int = 1,
         scale: float = 1.0) -> None:
    rows = run(quick, backend)
    if repeat > 1 or scale != 1.0:
        rows += diurnal_rows(repeat=max(repeat, 1), scale=scale,
                             policies=("fifo", "sept", "fc") if quick
                             else ("fifo", "sept", "eect", "rect", "fc"))
    emit(rows)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--repeat", type=int, default=1,
                    help="tile the 15-min slice into an N x 15-min stream "
                         "and add per-window diurnal tail rows")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="scale the trace's per-minute rates")
    args = ap.parse_args()
    main(args.quick, args.backend, repeat=args.repeat, scale=args.scale)
