"""Table III (main results): response time + stretch for all six strategies.

Reproduces the paper aggregate rows; prints ours vs paper side by side."""

from .common import emit, run_config

# paper Table III (R_avg seconds, S_avg) for 10 cores
PAPER_10 = {
    (30, "baseline"): (14.78, 261.6), (30, "fifo"): (36.42, 1000.6),
    (30, "sept"): (12.52, 104.1), (30, "eect"): (13.22, 166.7),
    (30, "rect"): (12.15, 144.2), (30, "fc"): (10.67, 83.6),
    (60, "baseline"): (123.36, 3608.8), (60, "fifo"): (101.76, 2959.5),
    (60, "sept"): (25.14, 164.5), (60, "eect"): (40.93, 766.2),
    (60, "rect"): (40.42, 763.8), (60, "fc"): (22.65, 134.2),
    (120, "baseline"): (340.28, 10098.5), (120, "fifo"): (233.94, 6893.0),
    (120, "sept"): (54.96, 331.3), (120, "eect"): (102.92, 2194.4),
    (120, "rect"): (104.77, 2233.6), (120, "fc"): (49.48, 262.9),
}
PAPER_20 = {
    (60, "baseline"): (369.33, 10964.4), (60, "fifo"): (206.81, 6008.2),
    (60, "sept"): (50.62, 321.7), (60, "fc"): (42.92, 265.5),
}


def run(quick: bool = False) -> list[dict]:
    rows = []
    grid = ([(10, 60)] if quick else [(10, 30), (10, 60), (10, 120), (20, 60)])
    for cores, inten in grid:
        paper = PAPER_10 if cores == 10 else PAPER_20
        pols = ["baseline", "fifo", "sept", "eect", "rect", "fc"]
        if cores == 20:
            pols = ["baseline", "fifo", "sept", "fc"]
        for pol in pols:
            mode = "baseline" if pol == "baseline" else "ours"
            eff_pol = "fifo" if pol == "baseline" else pol
            seeds = 2 if quick else 3
            r = run_config(cores, inten, eff_pol, mode, seeds=seeds)
            pr, ps = paper.get((inten, pol), (float("nan"), float("nan")))
            rows.append({
                "name": f"table3/c{cores}_v{inten}_{pol}",
                "us_per_call": r["R_avg"] * 1e6,
                "derived": (f"R_avg={r['R_avg']:.2f};paper_R={pr:.2f};"
                            f"S_avg={r['S_avg']:.0f};paper_S={ps:.0f};"
                            f"R_p99={r['R_p99']:.1f}"),
            })
    return rows


def main(quick: bool = False) -> None:
    emit(run(quick))


if __name__ == "__main__":
    main()
