"""Table III (main results): response time + stretch for all six strategies.

Reproduces the paper aggregate rows; prints ours vs paper side by side.
The whole table is one ragged SweepSpec (policy x cores x intensity) run
through the parallel sweep engine."""

from .common import emit

from repro.core import SweepSpec, run_sweep

# paper Table III (R_avg seconds, S_avg) for 10 cores
PAPER_10 = {
    (30, "baseline"): (14.78, 261.6), (30, "fifo"): (36.42, 1000.6),
    (30, "sept"): (12.52, 104.1), (30, "eect"): (13.22, 166.7),
    (30, "rect"): (12.15, 144.2), (30, "fc"): (10.67, 83.6),
    (60, "baseline"): (123.36, 3608.8), (60, "fifo"): (101.76, 2959.5),
    (60, "sept"): (25.14, 164.5), (60, "eect"): (40.93, 766.2),
    (60, "rect"): (40.42, 763.8), (60, "fc"): (22.65, 134.2),
    (120, "baseline"): (340.28, 10098.5), (120, "fifo"): (233.94, 6893.0),
    (120, "sept"): (54.96, 331.3), (120, "eect"): (102.92, 2194.4),
    (120, "rect"): (104.77, 2233.6), (120, "fc"): (49.48, 262.9),
}
PAPER_20 = {
    (60, "baseline"): (369.33, 10964.4), (60, "fifo"): (206.81, 6008.2),
    (60, "sept"): (50.62, 321.7), (60, "fc"): (42.92, 265.5),
}

ALL_POLICIES = ("baseline", "fifo", "sept", "eect", "rect", "fc")


def _grid(quick: bool) -> list[tuple[int, int]]:
    return [(10, 60)] if quick else [(10, 30), (10, 60), (10, 120), (20, 60)]


def spec(quick: bool = False, backend: str = "reference") -> SweepSpec:
    grid = set(_grid(quick))
    return SweepSpec(
        policies=ALL_POLICIES,
        cores=tuple(sorted({c for c, _ in grid})),
        intensities=tuple(sorted({v for _, v in grid})),
        seeds=2 if quick else 3,
        backends=(backend,),
        # paper only reports 4 strategies at 20 cores
        cell_filter=lambda c: (c.cores, c.intensity) in grid and not (
            c.cores == 20 and c.policy in ("eect", "rect")),
    )


def run(quick: bool = False, backend: str = "reference") -> list[dict]:
    result = run_sweep(spec(quick, backend))
    rows = []
    for cores, inten in _grid(quick):
        paper = PAPER_10 if cores == 10 else PAPER_20
        pols = [p for p in ALL_POLICIES
                if not (cores == 20 and p in ("eect", "rect"))]
        for pol in pols:
            agg = result.find(policy=pol, cores=cores, intensity=inten)
            pr, ps = paper.get((inten, pol), (float("nan"), float("nan")))
            rows.append({
                "name": f"table3/c{cores}_v{inten}_{pol}",
                "us_per_call": agg["R_avg"] * 1e6,
                "derived": (f"R_avg={agg['R_avg']:.2f};paper_R={pr:.2f};"
                            f"S_avg={agg['S_avg']:.0f};paper_S={ps:.0f};"
                            f"R_p99={agg['R_p99']:.1f}"),
            })
    return rows


def main(quick: bool = False, backend: str = "reference") -> None:
    emit(run(quick, backend))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="reference")
    args = ap.parse_args()
    main(args.quick, args.backend)
