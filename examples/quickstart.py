"""Quickstart: the paper's scheduler on a real JAX serving node.

Two endpoints (a cheap one and an expensive one) receive a burst; we run
the same burst under FIFO and under the paper's Fair-Choice policy and
print the response-time statistics.  Everything executes for real (tiny
models, XLA on CPU).

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config
from repro.models import scale_down
from repro.serving import Endpoint, ServingEngine


def make_engine(policy: str) -> ServingEngine:
    cheap = scale_down(get_config("qwen3_1_7b"))
    heavy = scale_down(get_config("deepseek_7b"), layers=4, d_model=128,
                       d_ff=256)
    return ServingEngine(
        [Endpoint("chat-mini", cheap, prompt_len=2, gen_len=2),
         Endpoint("summarize-long", heavy, prompt_len=4, gen_len=24)],
        slots=2, policy=policy)


def main() -> None:
    for policy in ("fifo", "fc"):
        eng = make_engine(policy)
        # estimator warm-up (the paper's warm-up phase)
        for _ in range(3):
            eng.submit("chat-mini")
            eng.submit("summarize-long")
        eng.run(max_wall_s=120)
        eng.completed.clear()
        # the measured burst: many cheap calls stuck behind heavy ones
        for _ in range(4):
            eng.submit("summarize-long")
        for _ in range(10):
            eng.submit("chat-mini")
        eng.run(max_wall_s=240)
        s = eng.summary()
        print(f"policy={policy:5s}  n={s['n']:3d}  "
              f"R_avg={s['R_avg']*1e3:7.1f} ms  "
              f"R_p50={s['R_p50']*1e3:7.1f} ms  "
              f"R_p95={s['R_p95']*1e3:7.1f} ms")
    print("\nFair-Choice should cut the mean/median sharply: cheap calls "
          "no longer wait behind the long generations (paper §VII).")


if __name__ == "__main__":
    main()
