"""A 200+-cell policy-comparison sweep through the parallel engine.

Sweeps all five node-local policies over intensity x cores x arrival
process x seeds (270 cells by default) and prints a policy league table per
arrival process, plus the parallel-runner speedup.  This is the shape of
experiment the paper runs per table -- here it is one declarative spec.

``--availability`` appends a multi-failure row: a 4-node pull cluster under
single kills, correlated double kills and a rolling restart
(``SweepCell.fail_spec`` / ``rolling_restart``), swept through the scan
backend, reporting lost-call counts and the tail cost of each outage shape.

Usage:
    PYTHONPATH=src python examples/sweep_grid.py [--quick] [--workers N]
                                                 [--csv out.csv] [--json out.json]
                                                 [--plot DIR] [--availability]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.core import SweepSpec, run_sweep  # noqa: E402

POLICIES = ("fifo", "sept", "eect", "rect", "fc")


def build_spec(quick: bool, backend: str = "reference") -> SweepSpec:
    # backend="cross-check" validates the fast path against the reference
    # on every eligible cell (raises BackendMismatchError on >1% drift)
    validate = "cross-check" if backend == "cross-check" else None
    backends = ("reference",) if backend == "cross-check" else (backend,)
    if quick:
        return SweepSpec(policies=POLICIES, intensities=(30,), cores=(5,),
                         arrivals=("uniform", "poisson"), seeds=2,
                         backends=backends, validate=validate)
    return SweepSpec(
        policies=POLICIES,                      # 5
        intensities=(30, 60, 90),               # x3
        cores=(5, 10),                          # x2
        arrivals=("uniform", "poisson", "mmpp"),  # x3
        seeds=3,                                # x3  -> 270 cells
        backends=backends,
        validate=validate,
    )


def availability_row(quick: bool, backend: str = "scan") -> None:
    """Multi-failure sweep: the same burst under increasingly correlated
    outages, one aggregated line per kill schedule."""
    from repro.core import SweepSpec, rolling_restart, run_sweep

    scenarios = {
        None: "healthy",
        ((0, 10.0),): "kill n0@10",
        ((0, 10.0), (1, 10.0)): "kill n0+n1@10",
        rolling_restart(3, 10.0, 20.0): "rolling 3@10/+20",
    }
    spec = SweepSpec(
        policies=("fc",),
        nodes=(4,), cores=(6,),
        intensities=(15,) if quick else (25,),
        fail_specs=tuple(scenarios),
        seeds=2 if quick else 3,
        backends=(backend,),
    )
    result = run_sweep(spec, workers=1)
    print("\n== availability: kill schedules on a 4-node pull cluster "
          f"(backend={backend}) ==")
    for row in result.aggregate():
        # label by the row's own fail_spec, never by position
        name = scenarios[row["fail_spec"]]
        print(f"  {name:18s} lost={row['failures']:5.1f} "
              f"R_avg={row['R_avg']:7.2f}  R_p95={row['R_p95']:7.2f}  "
              f"makespan={row['max_c']:7.1f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--csv", default=None)
    ap.add_argument("--json", default=None)
    ap.add_argument("--plot", default=None, metavar="DIR",
                    help="render fig5-style figures from the sweep into DIR")
    ap.add_argument("--backend", default="reference",
                    help="simulation backend: reference|vectorized|scan|"
                         "auto|cross-check")
    ap.add_argument("--availability", action="store_true",
                    help="also run the multi-failure availability row "
                         "(kill schedules incl. a rolling restart)")
    args = ap.parse_args()

    spec = build_spec(args.quick, args.backend)
    cells = spec.cells()
    print(f"sweep: {len(cells)} cells "
          f"({len(spec.policies)} policies x {len(spec.intensities)} "
          f"intensities x {len(spec.cores)} cores x "
          f"{len(spec.arrivals)} arrival processes x seeds) "
          f"[backend={args.backend}]")

    if sys.stdout.isatty():
        progress = lambda i, n: print(f"  {i}/{n} cells", end="\r",  # noqa: E731
                                      flush=True)
    else:
        progress = lambda i, n: (i % max(1, n // 10) == 0 and  # noqa: E731
                                 print(f"  {i}/{n} cells", flush=True))
    result = run_sweep(spec, workers=args.workers, progress=progress)
    print(f"done in {result.wall_s:.1f}s on {result.workers} workers")

    # serial reference from a stratified sample of the *actual* grid (every
    # k-th cell), so heavy cells are represented in the estimate
    from repro.core import run_cell
    stride = max(1, len(cells) // 10)
    sample = cells[::stride]
    t1 = time.monotonic()
    for cell in sample:
        run_cell(cell)
    est_serial = (time.monotonic() - t1) / len(sample) * len(cells)
    print(f"estimated serial wall: {est_serial:.1f}s "
          f"-> speedup ~{est_serial / max(result.wall_s, 1e-9):.1f}x")

    # league table: mean response by policy, per arrival process
    agg = result.aggregate()
    for arrival in spec.arrivals:
        print(f"\n== arrival: {arrival} (R_avg seconds, mean over grid) ==")
        for pol in spec.policies:
            rows = [r for r in agg
                    if r["policy"] == pol and r["arrival"] == arrival]
            mean_r = sum(r["R_avg"] for r in rows) / len(rows)
            mean_s = sum(r["S_avg"] for r in rows) / len(rows)
            print(f"  {pol:>5}: R_avg={mean_r:7.2f}  S_avg={mean_s:8.1f}")

    if args.csv:
        result.to_csv(args.csv)
        print(f"\nwrote {args.csv}")
    if args.json:
        result.to_json(args.json)
        print(f"wrote {args.json}")
    if args.plot:
        from benchmarks.plots import render_rows
        for p in render_rows(result.aggregate(), args.plot,
                             metrics=("R_avg", "R_p95")):
            print(f"wrote {p}")
    if args.availability:
        backend = ("scan" if args.backend in ("reference", "cross-check")
                   else args.backend)
        availability_row(args.quick, backend=backend)


if __name__ == "__main__":
    main()
