"""Reproduce the paper's single-node experiment grid (Tables II/III).

Runs the calibrated discrete-event simulator over (cores x intensity x
policy) exactly per §V's protocol (warm-up, 60 s uniform burst, 5 seeds)
and prints our numbers next to the published ones.

    PYTHONPATH=src python examples/paper_reproduction.py [--fast]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import generate_burst, simulate_single_node, summarize

PAPER = {  # (cores, intensity, policy) -> (R_avg, S_avg) from Table III
    (10, 40, "baseline"): (64.43, 1837.1), (10, 40, "fifo"): (58.29, 1647.4),
    (10, 40, "sept"): (17.01, 130.9), (10, 40, "eect"): (21.36, 312.6),
    (10, 40, "rect"): (20.37, 297.6), (10, 40, "fc"): (14.52, 95.2),
    (20, 60, "baseline"): (369.33, 10964.4), (20, 60, "fifo"): (206.81, 6008.2),
    (20, 60, "sept"): (50.62, 321.7), (20, 60, "fc"): (42.92, 265.5),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    seeds = 2 if args.fast else 5

    print(f"{'config':24s} {'R_avg':>8s} {'paper':>8s} {'S_avg':>9s} "
          f"{'paper':>9s}")
    for (cores, inten, pol), (pr, ps) in PAPER.items():
        mode = "baseline" if pol == "baseline" else "ours"
        eff = "fifo" if pol == "baseline" else pol
        R, S = [], []
        for seed in range(seeds):
            reqs = generate_burst(cores=cores, intensity=inten, seed=seed)
            simulate_single_node(reqs, cores=cores, policy=eff, mode=mode)
            s = summarize(reqs)
            R.append(s.response_avg)
            S.append(s.stretch_avg)
        print(f"c{cores}/v{inten}/{pol:9s} {np.mean(R):8.2f} {pr:8.2f} "
              f"{np.mean(S):9.0f} {ps:9.0f}")
    print("\nKey claims: SEPT/FC cut mean response ~3.5-4x and stretch "
          "~12-18x vs FIFO; ours-FIFO beats stock OpenWhisk under load.")


if __name__ == "__main__":
    main()
