"""End-to-end training driver: ~100M-param model, few hundred steps on CPU,
with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_small.py [--steps N] [--tiny]

The model is a scaled qwen3-family decoder (the same code path the dry-run
lowers onto the 256/512-chip meshes).  Kill and re-run mid-training to see
the checkpoint resume.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses

from repro.configs import get_config
from repro.models.config import LayerSpec
from repro.training import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-sized model (CI)")
    args = ap.parse_args()

    base = get_config("qwen3_1_7b")
    if args.tiny:
        cfg = dataclasses.replace(
            base, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
            d_ff=128, vocab=512, vocab_pad_multiple=16,
            period=(LayerSpec(),), remat=False)
        tcfg = TrainConfig(steps=min(args.steps, 20), global_batch=4,
                           seq_len=64, checkpoint_every=10,
                           checkpoint_dir="/tmp/repro_train_tiny")
    else:
        # ~100M params: 12L x 768 x 12H
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_head=64, d_ff=2048, vocab=32768, vocab_pad_multiple=256,
            period=(LayerSpec(),), remat=False)
        tcfg = TrainConfig(steps=args.steps, global_batch=8, seq_len=256,
                           checkpoint_every=50,
                           checkpoint_dir="/tmp/repro_train_100m")
    n = cfg.param_count()
    print(f"model: {n/1e6:.1f}M params; {tcfg.steps} steps, "
          f"batch {tcfg.global_batch} x {tcfg.seq_len}")
    out = train(cfg, tcfg)
    first = out["losses"][0][1] if out["losses"] else float("nan")
    print(f"loss {first:.3f} -> {out['final_loss']:.3f}")


if __name__ == "__main__":
    main()
