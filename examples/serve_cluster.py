"""Multi-worker serving with fault tolerance, stragglers and autoscaling.

Demonstrates the cluster layer (paper §VIII + large-scale extensions):
  1. Fig. 6: stock OpenWhisk on 4 nodes vs Fair-Choice on 3;
  2. a node crash mid-burst with pull-model recovery;
  3. a slow (straggler) node with hedged backup requests;
  4. queue-depth autoscaling under overload.

    PYTHONPATH=src python examples/serve_cluster.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import (
    Cluster,
    ClusterConfig,
    generate_burst,
    simulate_baseline_cluster,
    simulate_cluster,
    summarize,
)


def section(title):
    print(f"\n=== {title} ===")


def main() -> None:
    section("Fig. 6: fewer machines, better service (2376 calls, 60 s burst)")
    for label, fn in [
        ("openwhisk@4", lambda r: simulate_baseline_cluster(r, nodes=4)),
        ("fair-choice@4", lambda r: simulate_cluster(r, nodes=4, policy="fc")),
        ("fair-choice@3", lambda r: simulate_cluster(r, nodes=3, policy="fc")),
    ]:
        R, p75, p95 = [], [], []
        for seed in range(2):
            reqs = generate_burst(cores=72, intensity=30, seed=seed)
            s = summarize(fn(reqs).requests)
            R.append(s.response_avg); p75.append(s.response_pct[75])
            p95.append(s.response_pct[95])
        print(f"{label:15s} R_avg={np.mean(R):6.1f}s  p75={np.mean(p75):6.1f}s"
              f"  p95={np.mean(p95):6.1f}s")

    section("fault tolerance: node1 dies at t=10s (pull model re-queues)")
    reqs = generate_burst(cores=36, intensity=30, seed=0)
    cfg = ClusterConfig(nodes=2, cores_per_node=18, policy="fc",
                        assignment="pull")
    cluster = Cluster(cfg, warm_functions=sorted({r.fn for r in reqs}))
    cluster.fail_node(1, at=10.0)
    res = cluster.run(reqs)
    print(f"in-flight lost at crash: {res.failures}; "
          f"completed {len(res.requests)}/{len(reqs)} "
          f"(everything recovered on node0)")

    section("stragglers: node1 at 20% speed (blind push), work stealing")
    for backups in (False, True):
        p95 = []
        for seed in range(2):
            reqs = generate_burst(cores=20, intensity=20, seed=seed)
            res = simulate_cluster(reqs, nodes=2, cores_per_node=10,
                                   policy="fc", assignment="push",
                                   lb="round_robin", backup_requests=backups,
                                   node_speeds={1: 0.2})
            p95.append(summarize(res.requests).response_pct[95])
        print(f"stealing={str(backups):5s}  p95={np.mean(p95):6.1f}s"
              + (f"  (steals: {res.backups_issued})" if backups else ""))

    section("elastic scaling: overload triggers provisioning (30 s spin-up)")
    reqs = generate_burst(cores=10, intensity=120, seed=0)
    res = simulate_cluster(reqs, nodes=1, cores_per_node=10, policy="fc",
                           autoscale=True, provision_delay_s=30.0,
                           scale_up_queue_per_slot=2.0)
    s = summarize(res.requests)
    print(f"nodes 1 -> {res.nodes_used}; makespan {s.max_completion:.0f}s; "
          f"R_avg {s.response_avg:.1f}s")


if __name__ == "__main__":
    main()
